"""Property-based tests (hypothesis) on system invariants.

hypothesis is a dev-only dependency (requirements-dev.txt); on a clean
checkout without it the module skips instead of failing collection.
"""

import pytest

hypothesis = pytest.importorskip("hypothesis")

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.configs.base import ModelConfig
from repro.core import advisor, quantization as q
from repro.core.gemm_model import GEMM, estimate
from repro.core.hardware import TPU_V5E, A100_40GB
from repro.data.pipeline import synthetic_tokens
from repro.optim.adamw import dequantize_i8, quantize_i8

SET = settings(deadline=None, max_examples=40)

dims = st.integers(min_value=1, max_value=16384)
small_dims = st.integers(min_value=1, max_value=512)


@SET
@given(m=dims, n=dims, k=dims)
def test_tile_utilization_in_unit_interval(m, n, k):
    for hw in (TPU_V5E, A100_40GB):
        u = q.tile_utilization(m, n, k, hw)
        assert 0 < u <= 1.0


@SET
@given(m=dims, n=dims, k=dims, batch=st.integers(1, 64))
def test_estimate_respects_roofline(m, n, k, batch):
    g = GEMM("g", m, k, n, batch=batch)
    e = estimate(g, TPU_V5E)
    # achieved throughput can never exceed peak
    assert e.achieved_tflops <= TPU_V5E.peak_flops / 1e12 + 1e-6
    assert e.time_s >= g.flops / TPU_V5E.peak_flops - 1e-12


@SET
@given(x=dims, mult=st.sampled_from([8, 16, 64, 128, 256]))
def test_round_up_properties(x, mult):
    r = q.round_up(x, mult)
    assert r >= x and r % mult == 0 and r - x < mult


@SET
@given(n=st.integers(1, 2 ** 30))
def test_pow2_factor_divides(n):
    f = q.pow2_factor(n)
    assert n % f == 0
    assert f & (f - 1) == 0  # power of two


@SET
@given(dim=dims, shards=st.sampled_from([1, 2, 4, 8, 16, 32]))
def test_shard_quantization_bounds(dim, shards):
    u = q.shard_quantization(dim, shards)
    assert 0 < u <= 1
    if dim % shards == 0:
        assert u == 1.0


@SET
@given(h_mult=st.integers(2, 40), heads=st.sampled_from([8, 16, 20, 32, 40]))
def test_advisor_proposals_preserve_params_and_help(h_mult, heads):
    h = 128 * h_mult
    if h % heads:
        return
    cfg = ModelConfig(name="p", family="dense", num_layers=8, d_model=h,
                      num_heads=heads, num_kv_heads=heads, d_ff=4 * h,
                      vocab_size=50257, mlp_type="gelu")
    props = advisor.advise(cfg, param_tolerance=0.03)
    for p in props[:4]:
        assert abs(p.param_delta) <= 0.03 + 1e-9
        assert p.predicted_speedup > 0


@SET
@given(shape=st.sampled_from([(7,), (128,), (130,), (4, 33), (2, 3, 5)]),
       seed=st.integers(0, 2 ** 16))
def test_int8_quantization_roundtrip_error(shape, seed):
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), shape)) * 3.0
    qd = quantize_i8(jnp.asarray(x))
    back = np.asarray(dequantize_i8(qd, shape))
    # blockwise absmax int8: error bounded by scale/2 per block
    err = np.abs(back - x)
    bound = np.max(np.abs(x)) / 127.0 + 1e-7
    assert np.max(err) <= bound * 1.01


@SET
@given(seed=st.integers(0, 2 ** 20), step=st.integers(0, 10 ** 6),
       batch=st.integers(1, 8), seq=st.integers(1, 128),
       vocab=st.integers(2, 200000))
def test_synthetic_tokens_deterministic_and_in_range(seed, step, batch, seq, vocab):
    a = synthetic_tokens(seed, step, batch, seq, vocab)
    b = synthetic_tokens(seed, step, batch, seq, vocab)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < vocab


@SET
@given(v=st.integers(1, 300000))
def test_padded_vocab_invariants(v):
    cfg = ModelConfig(name="v", family="dense", num_layers=1, d_model=128,
                      num_heads=2, num_kv_heads=2, d_ff=256, vocab_size=v)
    pv = cfg.padded_vocab_size
    assert pv >= v and pv % 128 == 0 and pv - v < 128


# -- block pool (prefix cache + copy-on-write) -------------------------------
#
# hypothesis drives the pure-host BlockPool state machine with random
# alloc/fork/append/release programs against a shadow model of every block's
# contents.  Invariants (also in BlockPool.check, asserted after every op):
# refcounts are exact, no block is simultaneously free/cached/referenced,
# free + cached + referenced == total, writes only ever land in refcount-1
# blocks (copy-on-write), and every live sequence always reads back exactly
# its own tokens.  A seeded twin of this driver runs without hypothesis in
# test_prefix_cache.py.

_pool_ops = st.lists(
    st.tuples(st.integers(0, 3), st.integers(0, 2 ** 16)),
    min_size=1, max_size=80)


@settings(deadline=None, max_examples=25)
@given(ops=_pool_ops, block_size=st.sampled_from([2, 3, 4]),
       num_blocks=st.sampled_from([8, 12, 24]))
def test_block_pool_cow_and_accounting(ops, block_size, num_blocks):
    from repro.serving.engine import BlockPool, PoolExhausted

    bs, vocab = block_size, 37
    pool = BlockPool(num_blocks, bs)
    mem = {b: [None] * bs for b in range(num_blocks)}
    live = []                                   # (seq, tokens)
    prefixes = [[(7 * j + k) % vocab for j in range(bs * 2)] for k in (0, 1)]

    def write(seq, pos, tok):
        blk = seq.table[pos // bs]
        assert pool.ref[blk] == 1, "write reached a shared block"
        mem[blk][pos % bs] = tok

    for op, payload in ops:
        if op == 0:                             # admit a prompt
            base = prefixes[payload % 2] if payload % 4 else []
            n_tail = 1 + payload % (2 * bs)
            tokens = base + [(payload + 13 * i) % vocab
                             for i in range(n_tail)]
            try:
                seq, cows = pool.alloc_sequence(tokens)
            except PoolExhausted:
                pool.check()
                continue
            for c in cows:
                mem[c.dst] = list(mem[c.src])
            p = seq.num_cached
            for j in range(p // bs):
                assert mem[seq.table[j]] == tokens[j * bs:(j + 1) * bs]
            for pos in range(p, len(tokens)):
                write(seq, pos, tokens[pos])
            pool.commit(seq, tokens)
            live.append((seq, list(tokens)))
        elif op == 1 and live:                  # one decode append
            seq, tokens = live[payload % len(live)]
            try:
                c = pool.prepare_append(seq)
            except PoolExhausted:
                pool.check()
                continue
            if c is not None:
                mem[c.dst] = list(mem[c.src])
            tok = payload % vocab
            write(seq, seq.length, tok)
            pool.advance(seq)
            tokens.append(tok)
        elif op == 2 and live:                  # fork
            seq, tokens = live[payload % len(live)]
            live.append((pool.fork(seq), list(tokens)))
        elif op == 3 and live:                  # release
            seq, _ = live.pop(payload % len(live))
            pool.release(seq)
        pool.check()
        for seq, tokens in live:                # sequence isolation
            for pos in range(seq.length):
                assert mem[seq.table[pos // bs]][pos % bs] == tokens[pos]
    assert all(r >= 0 for r in pool.ref)
    assert (pool.num_free_blocks + pool.num_cached_blocks
            + pool.num_referenced_blocks == num_blocks)
